"""Benchmarks reproducing every paper table/figure (one function each).

CSV row convention: ``name,us_per_call,derived`` where `derived` encodes the
reproduced quantity and its match against the published value.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core import cost_model as cm
from repro.core import paper_tables as pt
from repro.core.apps import aes_paper_accounting, evaluate_app
from repro.workloads import get_workload, workload_names
from repro.core.cost_model import Layout, utilization, vector_add_cost
from repro.core.microkernels import table5_model_row
from repro.core.planner import (
    hybrid_profitability_threshold, plan, transpose_sensitivity,
)


def t2_primitives() -> list[str]:
    """Table 2: primitive cycle costs."""
    rows = []
    checks = [
        ("bp_logic", lambda: cm.BP_LOGIC, 1),
        ("bp_add", lambda: cm.BP_ADD, 1),
        ("bp_sub", lambda: cm.BP_SUB, 2),
        ("bp_mult32", lambda: cm.bp_mult(32), 34),
        ("bs_add1", lambda: cm.BS_ADD1, 1),
        ("bs_shift", lambda: cm.BS_SHIFT, 0),
        ("bs_mux1", lambda: cm.BS_MUX1, 4),
    ]
    for name, fn, want in checks:
        us = time_us(fn)
        got = fn()
        rows.append(emit(f"t2.{name}", us,
                         f"cycles={got};paper={want};match={got == want}"))
    return rows


def t3_latency() -> list[str]:
    """Table 3: 32-bit kernel compute latency."""
    model = {
        "vector_add": (cm.BP_ADD, cm.bs_add(32)),
        "vector_mult": (cm.bp_mult(32), cm.bs_mult(32)),
        "min_max": (cm.minmax_bp(32), cm.minmax_bs(32)),
        "if_then_else": (cm.if_then_else_bp(32), cm.if_then_else_bs(32)),
    }
    rows = []
    for k, want in sorted(pt.TABLE3.items()):
        us = time_us(lambda k=k: model[k])
        got = model[k]
        rows.append(emit(f"t3.{k}", us,
                         f"bp={got[0]};bs={got[1]};paper={want};"
                         f"match={got == want}"))
    return rows


def t4_batching() -> list[str]:
    """Table 4: vector-add latency vs size (batching effect)."""
    rows = []
    for r in pt.TABLE4:
        us = time_us(vector_add_cost, Layout.BP, r.elements)
        bp = vector_add_cost(Layout.BP, r.elements).total
        bs = vector_add_cost(Layout.BS, r.elements).total
        ok = (bp, bs) == (r.bp_cycles, r.bs_cycles)
        rows.append(emit(f"t4.n{r.elements}", us,
                         f"bp={bp};bs={bs};speedup={bs/bp:.2f};"
                         f"paper=({r.bp_cycles},{r.bs_cycles});match={ok}"))
    return rows


def t5_microkernels() -> list[str]:
    """Table 5: micro-kernel cycle breakdown (16-bit)."""
    kmap = {"1b Logic": "bitweave1", "2b Logic": "bitweave2",
            "4b Logic": "bitweave4"}
    rows = []
    for r in pt.TABLE5:
        name = kmap.get(r.variant, r.kernel) if r.kernel == "bitweave" \
            else r.kernel
        us = time_us(table5_model_row, name, Layout(r.mode))
        c = table5_model_row(name, Layout(r.mode))
        ok = (c.load, c.compute, c.readout, c.total) == \
            (r.load, r.compute, r.readout, r.total)
        rows.append(emit(f"t5.{r.kernel}.{r.mode}", us,
                         f"L{c.load}+C{c.compute}+R{c.readout}={c.total};"
                         f"paper={r.total};match={ok}"))
    return rows


def t6_applications() -> list[str]:
    """Table 6: application classification (22 apps)."""
    rows = []
    for app in workload_names("table6"):
        us = time_us(evaluate_app, app, repeat=1)
        r = evaluate_app(app)
        band = pt.TABLE6_BANDS[pt.TABLE6_APPS[app]]
        if band.category == "Hybrid recommended":
            ok = r["is_hybrid"] and r["hybrid_speedup"] > 1.05
            derived = (f"bs/bp={r['bs_over_bp']:.2f};"
                       f"hybrid_speedup={r['hybrid_speedup']:.2f};"
                       f"class=hybrid;match={ok}")
        else:
            ok = band.lo <= r["bs_over_bp"] <= band.hi
            derived = (f"bs/bp={r['bs_over_bp']:.3f};"
                       f"band=[{band.lo},{band.hi}];match={ok}")
        rows.append(emit(f"t6.{app}", us, derived))
    return rows


def t7_aes() -> list[str]:
    """Table 7 + Sec. 5.4: AES-128 stage costs, totals, hybrid, sensitivity,
    plus wall-time of the functional bitplane simulator (all 3 layouts)."""
    rows = []
    acc = aes_paper_accounting()
    for k in ("BP", "BS", "hybrid"):
        rows.append(emit(f"t7.total_{k}", 0.0,
                         f"cycles={acc[k]};paper={pt.AES_TOTALS[k]};"
                         f"match={acc[k] == pt.AES_TOTALS[k]}"))
    aes_phases = get_workload("aes").to_phases()
    p = plan(aes_phases)
    rows.append(emit("t7.dp_planner", time_us(plan, aes_phases, repeat=3),
                     f"cycles={p.total_cycles};speedup={p.hybrid_speedup:.2f};"
                     f"hand_schedule=6994;dp<=hand={p.total_cycles <= 6994}"))
    s = transpose_sensitivity(aes_phases, 10)
    rows.append(emit("t7.sensitivity_10x", 0.0,
                     f"runtime_pct=+{s['runtime_increase_pct']:.2f};"
                     f"speedup={s['hybrid_speedup']:.2f};paper=(+2.6,2.59)"))
    thr = hybrid_profitability_threshold(aes_phases)
    rows.append(emit("t7.hybrid_threshold", 0.0,
                     f"core_cycles={thr};paper_reference=51;"
                     f"hybrid_robust={thr > 51}"))
    # functional simulator wall time (FIPS-197 vector)
    from repro.pim import aes as sim
    key = np.arange(16, dtype=np.uint8)
    ptxt = np.arange(16, dtype=np.uint8)
    for name, fn in (("bp", sim.encrypt_bp), ("bs", sim.encrypt_bs),
                     ("hybrid", sim.encrypt_hybrid)):
        us = time_us(fn, ptxt, key, repeat=1)
        ok = bool(np.array_equal(fn(ptxt, key),
                                 sim.encrypt_reference(ptxt, key)))
        rows.append(emit(f"t7.sim_{name}", us, f"matches_reference={ok}"))
    return rows


def f8_vgg() -> list[str]:
    """Fig. 8: VGG-13 per-layer utilization."""
    rows = []
    for layer, ch, spatial in pt.FIG8_LAYERS:
        ops = int(ch * spatial * spatial / 9)
        us = time_us(utilization, Layout.BS, ops, 16)
        ubs = utilization(Layout.BS, ops, 16)
        ubp = utilization(Layout.BP, ops, 16)
        qb = pt.FIG8_QUOTED_UTIL.get((layer, "BS"))
        qp = pt.FIG8_QUOTED_UTIL.get((layer, "BP"))
        match = all(q is None or abs(u - q) < 0.005
                    for u, q in ((ubs, qb), (ubp, qp)))
        rows.append(emit(f"f8.{layer}", us,
                         f"bs={ubs:.3f};bp={ubp:.3f};"
                         f"paper=({qb},{qp});match={match}"))
    return rows


ALL = [t2_primitives, t3_latency, t4_batching, t5_microkernels,
       t6_applications, t7_aes, f8_vgg]
