"""Serving-path benchmarks + oracle rows.

Rows:

* ``serve.plan_service``   -- per-request plan compilation through the
  content-addressed cache: cold pass then warm pass over one traffic
  sample; derived reports the warm hit rate (oracle: warm pass is 100%
  cache-served).
* ``serve.batch_amortize`` -- oracle: phase-grouped batching never loses
  (``transpose_cycles_saved >= 0`` and group latency <= the worst
  ungrouped member) and every group's members share one signature.
* ``serve.bench_scenario`` -- one in-process ``run_serve_bench`` pass
  (quick: 128 requests, full: 1024); derived carries throughput and the
  cache hit rate.

All backends are resolved through ``repro.workloads.get_backend`` -- the
benches construct no backend classes directly.
"""
from __future__ import annotations

from benchmarks.common import emit, quick, time_us


def _n_requests() -> int:
    return 128 if quick() else 1024


def bench_plan_service():
    from repro.serve import PlanCache, PlanService, TrafficMix

    cache = PlanCache(persist=False)
    service = PlanService(cache=cache)
    requests = TrafficMix.default().sample(_n_requests(), seed=0)
    service.compile_many(requests)          # cold: fills the cache
    cold_rate = cache.hit_rate
    cold_misses = cache.misses

    def warm():
        service.compile_many(requests)

    us = time_us(warm)
    warm_ok = cache.misses == cold_misses   # warm passes are 100% served
    us_per_req = us / len(requests)
    return [emit("serve.plan_service", us_per_req,
                 f"requests={len(requests)};cold_hit_rate={cold_rate:.3f};"
                 f"warm_all_hit={warm_ok};match={warm_ok}")]


def bench_batch_amortize():
    from repro.serve import PhaseBatcher, PlanService, TrafficMix

    service = PlanService(persist=False)
    compiled = service.compile_many(
        TrafficMix.default().sample(_n_requests(), seed=1))
    groups = PhaseBatcher(max_batch=32).group(compiled)
    ok = True
    for g in groups:
        ok &= all(m.signature == g.signature for m in g.members)
        ok &= g.transpose_cycles_saved >= 0
        worst_alone = max(
            (c + t for c, t in zip(g.member_compute_cycles(),
                                   g.member_transpose_cycles())),
            default=0)
        ok &= g.latency_cycles <= worst_alone + g.amortized_transpose_cycles
    saved = sum(g.transpose_cycles_saved for g in groups)
    return [emit("serve.batch_amortize", 0.0,
                 f"groups={len(groups)};saved_cycles={saved};match={ok}")]


def bench_scenario():
    import tempfile

    from repro.serve import run_serve_bench

    with tempfile.TemporaryDirectory() as d:
        payload = run_serve_bench(_n_requests(), seed=0, cache_dir=d)
    return [emit("serve.bench_scenario",
                 payload["elapsed_s"] * 1e6 / payload["requests"],
                 f"requests={payload['requests']};"
                 f"hit_rate={payload['cache']['hit_rate']:.3f};"
                 f"rps={payload['throughput_rps']:.0f}")]


ALL = [bench_plan_service, bench_batch_amortize, bench_scenario]
