"""Benchmark driver: one function per paper table/figure + kernel,
executor, and roofline benches. Prints ``name,us_per_call,derived`` CSV rows.

``--quick`` (or REPRO_BENCH_QUICK=1) is the CI smoke mode: one timed
iteration per bench -- it exists so the kernel and table entrypoints can't
silently rot between full benchmark runs. The executed-vs-analytic table
(benchmarks/executor_bench.py) is still written to
``bench-artifacts/executed_vs_analytic.csv`` in quick mode; CI uploads it
as a build artifact.
"""
from __future__ import annotations

import argparse
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: repeat=1, correctness-path only")
    args = ap.parse_args(argv)
    if args.quick:
        os.environ["REPRO_BENCH_QUICK"] = "1"

    # import AFTER the env knob so benches see the quick-mode setting
    from benchmarks import (
        executor_bench, kernels_bench, machine_bench, paper_tables_bench,
        pallas_bench, plan_bench, roofline_bench, serve_bench, sweep_bench,
    )

    print("name,us_per_call,derived")
    total, matched = 0, 0
    for mod in (paper_tables_bench, kernels_bench, pallas_bench,
                executor_bench, roofline_bench, sweep_bench, plan_bench,
                serve_bench, machine_bench):
        for fn in mod.ALL:
            for row in fn():
                total += 1
                # a row fails on an explicit mismatch or bench error;
                # informational rows (no match= field, missing-artifact
                # notices) don't gate
                if "match=False" not in row and "FAILED" not in row:
                    matched += 1
    print(f"# {matched}/{total} rows match published/oracle targets")
    return 0 if matched == total else 1


if __name__ == "__main__":
    sys.exit(main())
