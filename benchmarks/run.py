"""Benchmark driver: one function per paper table/figure + kernel and
roofline benches. Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations


def main() -> None:
    from benchmarks import kernels_bench, paper_tables_bench, roofline_bench

    print("name,us_per_call,derived")
    total, matched = 0, 0
    for mod in (paper_tables_bench, kernels_bench, roofline_bench):
        for fn in mod.ALL:
            for row in fn():
                total += 1
                if "match=True" in row or "match=" not in row:
                    matched += 1
    print(f"# {matched}/{total} rows match published/oracle targets")


if __name__ == "__main__":
    main()
