"""Roofline table from the dry-run artifacts (single-pod; see
EXPERIMENTS.md §Roofline). Emits one CSV row per (arch x shape) cell with
the three terms, the dominant bound, MFU, and useful-FLOPs fraction."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ARTIFACT_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def roofline() -> list[str]:
    rows = []
    pattern = os.path.join(ARTIFACT_DIR, "pod16x16", "*.json")
    files = sorted(glob.glob(pattern))
    if not files:
        rows.append(emit("roofline.missing", 0.0,
                         f"no artifacts under {pattern}; run "
                         "python -m repro.launch.dryrun --all first"))
        return rows
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        name = f"roofline.{rec['arch']}.{rec['shape']}"
        if rec["status"] == "skipped":
            rows.append(emit(name, 0.0, "skipped=long_500k_full_attention"))
            continue
        if rec["status"] != "ok":
            rows.append(emit(name, 0.0, f"FAILED={rec.get('error')}"))
            continue
        rl = rec["roofline"]
        rows.append(emit(
            name, rec.get("compile_s", 0) * 1e6,
            f"compute_ms={rl['compute_s']*1e3:.2f};"
            f"memory_ms={rl['memory_s']*1e3:.2f};"
            f"collective_ms={rl['collective_s']*1e3:.2f};"
            f"bound={rl['bound']};mfu={rl['mfu']:.3f};"
            f"useful_flops={rl['useful_flops_fraction']:.3f}"))
    return rows


ALL = [roofline]
