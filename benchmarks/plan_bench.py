"""Layout-plan compilation benchmarks + oracle rows.

Rows:

* ``plan.compile``            -- compile every Table-6 app at the paper
  geometry (repro.plan.compile_plan; one timed pass over the registry).
* ``plan.vs_legacy``          -- oracle: the DAG scheduler's plan total
  AND schedule equal an *independent* verbatim copy of the pre-refactor
  2-state phase DP for every Table-6 app (``match=``; `core.planner.plan`
  itself is now a shim over the same scheduler, so it cannot be the
  reference).
* ``plan.beats_statics``      -- oracle: ``total <= min(static_bp,
  static_bs)`` for every app across the full iso-area geometry family
  (the ISSUE-5 acceptance bound; ``match=``).
* ``plan.replay``             -- oracle: executor-replayed plan cycles of
  the 13 executable Table-5 kernels match the planner's prediction up to
  the documented Sec.-8 calibration deltas (``match=``).
"""
from __future__ import annotations

from benchmarks.common import emit, quick, time_us


def _apps():
    from repro.workloads import workload_names

    names = workload_names("table6")
    return names[:4] if quick() else names


def bench_compile():
    from repro.plan import compile_plan
    from repro.workloads import get_workload

    apps = _apps()

    def run():
        for app in apps:
            compile_plan(get_workload(app))

    us = time_us(run)
    return [emit("plan.compile", us, f"apps={len(apps)}")]


def _reference_dp(phases, sys):
    """The pre-refactor ``core.planner.plan`` DP, kept verbatim as an
    independent reference (the shipped ``plan`` is a shim over the new
    scheduler and cannot oracle it)."""
    from repro.core.cost_model import Layout
    from repro.core.transpose import transpose_cycles

    layouts = (Layout.BP, Layout.BS)
    INF = float("inf")
    cost, back = {}, []
    for lay in layouts:
        cost[lay] = phases[0].cycles(lay)
    for ph in phases[1:]:
        sw = transpose_cycles(ph.rows_bp, ph.rows_bs, "bp2bs", sys)
        new_cost, back_i = {}, {}
        for lay in layouts:
            best, best_prev = INF, None
            for prev in layouts:
                c = cost[prev] + (0 if prev == lay else sw) \
                    + ph.cycles(lay)
                if c < best:
                    best, best_prev = c, prev
            new_cost[lay] = best
            back_i[lay] = best_prev
        cost = new_cost
        back.append(back_i)
    end = min(layouts, key=lambda lay: cost[lay])
    sched = [end]
    for back_i in reversed(back):
        sched.append(back_i[sched[-1]])
    sched.reverse()
    return tuple(sched), int(cost[end])


def bench_vs_legacy():
    from repro.core.params import PAPER_SYSTEM
    from repro.plan import compile_plan
    from repro.workloads import get_workload

    ok = True
    for app in _apps():
        w = get_workload(app)
        p = compile_plan(w)
        sched, total = _reference_dp(w.to_phases(), PAPER_SYSTEM)
        ok &= p.total_cycles == total and p.schedule == sched
    return [emit("plan.vs_legacy", 0.0, f"match={ok}")]


def bench_beats_statics():
    from repro.plan import compile_plan
    from repro.sweep import iso_area_family
    from repro.workloads import get_workload

    geos = iso_area_family()
    if quick():
        geos = geos[:3]
    ok = True
    for app in _apps():
        w = get_workload(app)
        for g in geos:
            p = compile_plan(w, geometry=g)
            ok &= p.total_cycles <= min(p.static_bp, p.static_bs)
    return [emit("plan.beats_statics", 0.0,
                 f"apps={len(_apps())};geometries={len(geos)};match={ok}")]


def bench_replay():
    from repro.pim.programs import EXECUTABLE_KERNELS
    from repro.plan import compile_plan, replay_matches, replay_plan
    from repro.workloads import get_workload

    ok, n_rows = True, 0
    for kernel in EXECUTABLE_KERNELS:
        w = get_workload(f"mk/{kernel}")
        p = compile_plan(w)
        rows = replay_plan(p, w, execute=not quick())
        ok &= replay_matches(rows)
        n_rows += len(rows)
    return [emit("plan.replay", 0.0,
                 f"kernels={len(EXECUTABLE_KERNELS)};rows={n_rows};"
                 f"match={ok}")]


ALL = [bench_compile, bench_vs_legacy, bench_beats_statics, bench_replay]
