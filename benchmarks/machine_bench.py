"""Machine-level scheduling benchmarks + oracle rows.

Rows:

* ``machine.plan``       -- `plan_machine` of formula VGG16 at the paper
  geometry, one partition per array; oracle: the delta catalogue
  explains every machine-vs-planner cycle and N=1 reduces bit-for-bit.
* ``machine.execute``    -- the critical partition class of traced VGG16
  executed across all simulated arrays through `run_batched`
  (quick: 64 arrays, full: 1024); oracle: zero unexplained
  executed-vs-analytic rows and the batched-runner LRU stays bounded.
* ``machine.scaling``    -- the iso-area scaling curve (quick: 2 points);
  oracle: every feasible point's schedule is explained.
"""
from __future__ import annotations

from benchmarks.common import emit, quick, time_us


def bench_machine_plan():
    from repro.machine import plan_machine
    from repro.workloads import get_workload

    w = get_workload("vgg16")
    us = time_us(lambda: plan_machine(w))
    s = plan_machine(w)
    s1 = plan_machine(w, n_parts=1)
    ok = (s.explained and s1.total_cycles == s1.planner_total
          and not s1.deltas)
    return [emit("machine.plan", us,
                 f"N={s.n_partitions};classes={len(s.classes)};"
                 f"total={s.total_cycles};planner={s.planner_total};"
                 f"delta={s.delta_total};match={ok}")]


def bench_machine_execute():
    from repro.machine import execute_schedule, plan_machine
    from repro.pim.executor import batched_cache_stats
    from repro.sweep import Geometry
    from repro.workloads import get_workload

    arrays = 64 if quick() else 1024
    rows = 128 if quick() else 64
    w = get_workload("traced/vgg16")
    sched = plan_machine(w, Geometry(rows=rows, cols=512, arrays=arrays))

    def run():
        return execute_schedule(sched, w, functional=True,
                                collect_hlo=False)

    us = time_us(run)
    res = run()
    stats = batched_cache_stats()
    ok = (not res["unexplained"]
          and all(r["explained"] for r in res["rows"])
          and res["arrays_simulated"] >= arrays
          and stats["size"] <= stats["limit"])
    return [emit("machine.execute", us,
                 f"arrays={res['arrays_simulated']};"
                 f"programs={len(res['programs'])};"
                 f"cache_size={stats['size']};match={ok}")]


def bench_machine_scaling():
    from repro.machine import run_machine_bench
    from repro.sweep import iso_area_family

    fam = iso_area_family()
    geos = tuple(g for g in fam if g.rows in ((128, 512) if quick()
                                              else (64, 128, 512)))
    us = time_us(lambda: run_machine_bench(
        "vgg16", geometries=geos, execute=False, run_diff=False))
    payload = run_machine_bench("vgg16", geometries=geos, execute=False,
                                run_diff=False)
    pts = [p for p in payload["curve"] if "error" not in p]
    ok = bool(pts) and all(p["explained"] for p in pts) \
        and not payload["gate_failures"]
    return [emit("machine.scaling", us,
                 f"points={len(pts)}/{len(payload['curve'])};match={ok}")]


ALL = (bench_machine_plan, bench_machine_execute, bench_machine_scaling)
