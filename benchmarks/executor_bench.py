"""Micro-op executor benchmarks (ISSUE 2 tentpole).

Two benches:

* `executor_throughput` -- jit/vmap batched execution of 16-bit Table-5
  kernels across 8 simulated arrays (4096 elements) in one jitted call,
  with a semantics check against the integer oracle.
* `executed_vs_analytic` -- the full differential table (kernel x layout x
  width): executed program cycles vs the analytic `cost_model` compute
  formula.  The complete table is written to
  ``bench-artifacts/executed_vs_analytic.csv`` (also in --quick mode; CI
  uploads it as a build artifact); only rows with a nonzero delta are
  echoed as CSV bench rows, each gated on the delta being the documented
  one (DESIGN.md Sec. 8).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.core.cost_model import Layout
from repro.core.microkernels import MICROKERNELS
from repro.pim import executor as ex
from repro.pim import programs as pr
from repro.pim.bitserial import unpack

ARTIFACT_DIR = os.environ.get("REPRO_BENCH_ARTIFACT_DIR", "bench-artifacts")

_WIDTHS = (8, 16, 32)


def executor_throughput() -> list[str]:
    """1024+ elements of a 16-bit kernel across >= 8 arrays, one jitted
    call (the ISSUE-2 acceptance operating point)."""
    rows = []
    w, n_arrays, cols = 16, 8, 512          # 8 x 512 = 4096 elements
    rng = np.random.default_rng(0)
    for kernel, out_name in (("vector_add", "sum"), ("multu", "prod")):
        prog = pr.build(kernel, Layout.BS, width=w)
        a = rng.integers(0, 1 << w, (n_arrays, cols)).astype(np.uint64)
        b = rng.integers(0, 1 << w, (n_arrays, cols)).astype(np.uint64)
        cells = np.zeros((n_arrays, prog.rows, cols), bool)
        for i in range(n_arrays):
            c = ex.init_cells(prog, cols)
            c = ex.set_input(c, prog, "a", a[i])
            c = ex.set_input(c, prog, "b", b[i])
            cells[i] = np.asarray(c)
        cells = jnp.asarray(cells)
        us = time_us(
            lambda: np.asarray(ex.run_batched(prog, cells).cells), repeat=3)
        state = ex.run_batched(prog, cells)
        start, nr = prog.output_region(out_name)
        got = np.stack([unpack(state.cells[i, start:start + nr])
                        for i in range(n_arrays)])
        want = (a + b) % (1 << w) if kernel == "vector_add" else a * b
        ok = bool(np.array_equal(got, want))
        elems = n_arrays * cols
        rows.append(emit(
            f"exec.batched.{kernel}.BS.w{w}", us,
            f"arrays={n_arrays};elements={elems};cycles={prog.cycles};"
            f"melems_per_s={elems / max(us, 1e-9):.2f};match={ok}"))
    return rows


def executed_vs_analytic() -> list[str]:
    """Executed-vs-analytic mismatch table + CSV artifact."""
    rows = []
    csv = ["kernel,layout,width,executed,analytic,delta,expected_delta,note"]
    for name in pr.EXECUTABLE_KERNELS:
        for layout in (Layout.BP, Layout.BS):
            for w in _WIDTHS:
                n = 16 if name == "reduction" else None
                d = MICROKERNELS[name].executed_vs_analytic(layout, w, n=n)
                csv.append(
                    f"{name},{layout.value},{w},{d['executed']},"
                    f"{d['analytic']},{d['delta']},{d['expected_delta']},"
                    f"\"{d['note']}\"")
                if d["delta"] != 0 or d["delta"] != d["expected_delta"]:
                    documented = (d["delta"] == d["expected_delta"]
                                  and bool(d["note"]))
                    rows.append(emit(
                        f"exec.delta.{name}.{layout.value}.w{w}", 0.0,
                        f"executed={d['executed']};analytic={d['analytic']};"
                        f"delta={d['delta']};match={documented}"))
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, "executed_vs_analytic.csv")
    with open(path, "w") as f:
        f.write("\n".join(csv) + "\n")
    rows.append(emit("exec.delta.artifact", 0.0,
                     f"path={path};table_rows={len(csv) - 1}"))
    return rows


ALL = [executor_throughput, executed_vs_analytic]
