"""Sweep-engine benchmarks: one-jitted-call grid evaluation throughput vs
the scalar cost-model loop, plus a vectorized-vs-scalar oracle row.

Rows:

* ``sweep_grid_jit``      -- the full default grid (18 mk/* x 2 layouts x
  4 widths x 9 iso-area geometries) through `repro.sweep.vectorized.
  eval_grid` (compile excluded by the warmup call in `time_us`).
* ``sweep_scalar_loop``   -- the same grid through the scalar
  `microkernels.kernel_cost` path (the pre-sweep baseline; derived field
  reports the vectorized speedup).
* ``sweep_vs_scalar``     -- oracle row: both paths must agree exactly on
  a deterministic sample of grid cells (``match=``).
* ``sweep_cache_roundtrip`` -- run_sweep twice against a temp cache dir;
  derived field asserts the second call hits.
"""
from __future__ import annotations

import tempfile

import numpy as np

from benchmarks.common import emit, quick, time_us


def _grid_args():
    from repro.core.microkernels import MICROKERNELS
    from repro.sweep import iso_area_family

    kernel_ns = tuple(
        (k, 8192 if k == "relu" else 1024) for k in sorted(MICROKERNELS))
    widths = (4, 8, 16, 32)
    geo = iso_area_family()
    rows = [g.rows for g in geo]
    cols = [g.cols for g in geo]
    arrays = [g.arrays for g in geo]
    bw = [g.row_bandwidth_bits for g in geo]
    return kernel_ns, widths, rows, cols, arrays, bw


def _scalar_grid(kernel_ns, widths, geo_systems):
    from repro.core.cost_model import Layout
    from repro.core.microkernels import kernel_cost

    out = np.zeros((len(kernel_ns), 2, len(widths), len(geo_systems), 3),
                   np.int64)
    for k, (name, n) in enumerate(kernel_ns):
        for li, lay in enumerate((Layout.BP, Layout.BS)):
            for wi, w in enumerate(widths):
                for gi, s in enumerate(geo_systems):
                    c = kernel_cost(name, lay, n=n, width=w, sys=s)
                    out[k, li, wi, gi] = (c.load, c.compute, c.readout)
    return out


def bench_sweep_grid():
    from repro.sweep import iso_area_family
    from repro.sweep.vectorized import eval_grid

    kernel_ns, widths, rows, cols, arrays, bw = _grid_args()
    run = lambda: np.asarray(
        eval_grid(kernel_ns, widths, rows, cols, arrays, bw))
    us_vec = time_us(run)
    n_cells = len(kernel_ns) * 2 * len(widths) * len(rows)
    rows_out = [emit("sweep_grid_jit", us_vec, f"cells={n_cells}")]

    geo_systems = [g.system() for g in iso_area_family()]
    us_scalar = time_us(
        lambda: _scalar_grid(kernel_ns, widths, geo_systems),
        repeat=1 if quick() else 3)
    rows_out.append(emit("sweep_scalar_loop", us_scalar,
                         f"vec_speedup={us_scalar / max(us_vec, 1e-9):.1f}x"))

    vec = run()
    scalar = _scalar_grid(kernel_ns, widths, geo_systems)
    match = bool((vec.astype(np.int64) == scalar).all())
    rows_out.append(emit("sweep_vs_scalar", 0.0, f"match={match}"))
    return rows_out


def bench_sweep_cache():
    from repro.sweep import SweepSpec, run_sweep

    spec = SweepSpec.default(workloads=("mk/vector_add", "mk/multu"),
                             widths=(8, 16))
    with tempfile.TemporaryDirectory() as td:
        us = time_us(lambda: run_sweep(spec, cache_dir=td), repeat=1)
        hit = run_sweep(spec, cache_dir=td).cache["hit"]
    return [emit("sweep_cache_roundtrip", us, f"match={bool(hit)}")]


ALL = [bench_sweep_grid, bench_sweep_cache]
